//! Scenario (paper Fig. 1 / §1 example 2): a smart-home HVAC control
//! system. Sensor devices have weak CPUs, so the application is
//! *computation-sensitive*: it weights CompT and CompL (α = γ = 0.5) and
//! doesn't care about transmission.
//!
//! Expected behaviour per Table 3 / Table 4: FedTune pushes E down (small
//! E is better for both CompT and CompL) and settles M at a moderate
//! value balancing time (wants big M) against load (wants small M). The
//! tuners axis also runs the two non-paper policies on the same cells,
//! so the app can *choose* its tuner by measured Eq. (6) gain.
//!
//!     cargo run --release --example smart_home

use fedtune::config::ExperimentConfig;
use fedtune::experiment::Grid;
use fedtune::fedtune::tuner::TunerSpec;
use fedtune::overhead::Preference;

fn main() -> anyhow::Result<()> {
    let pref = Preference::new(0.5, 0.0, 0.5, 0.0).map_err(anyhow::Error::msg)?;
    let cfg = ExperimentConfig {
        dataset: "speech".into(), // voice-command control of the home
        model: "resnet-10".into(),
        seed: 7,
        ..ExperimentConfig::default()
    };

    // Candidate policies by spec string — the same grammar as
    // `fedtune run --tuner ...` (fixed is the baseline leg, so it is
    // not listed on the axis).
    let tuners = [
        TunerSpec::parse("fedtune").map_err(anyhow::Error::msg)?,
        TunerSpec::parse("stepwise:0.7:12").map_err(anyhow::Error::msg)?,
        TunerSpec::parse("population:4:10").map_err(anyhow::Error::msg)?,
    ];

    println!("smart-home HVAC: computation-sensitive (α=0.5, γ=0.5)\n");
    // `cache_from_env`: set FEDTUNE_CACHE_DIR=.fedtune-cache to reuse the
    // runs across examples/benches (the store dedupes the shared baseline
    // automatically; see `fedtune grid --help` for the CLI flags).
    let result = Grid::new(cfg)
        .preferences(&[pref])
        .tuners(&tuners)
        .seeds(&[7, 8, 9])
        .compare_baseline(true)
        .cache_from_env()
        .run()?;

    let mut best: Option<(&TunerSpec, f64)> = None;
    for spec in &tuners {
        let c = result
            .find_cell(|cell| cell.tuner == *spec)
            .expect("every tuner on the axis has a cell");
        let imp = c.improvement.expect("compare_baseline reports improvement");
        println!(
            "{:<18} {:+7.2}% (std {:.2}%) weighted-overhead reduction   \
             final M = {:.1}, E = {:.1}",
            spec.spec_string(),
            imp.mean,
            imp.std,
            c.final_m.mean,
            c.final_e.mean
        );
        if best.map(|(_, b)| imp.mean > b).unwrap_or(true) {
            best = Some((spec, imp.mean));
        }
    }
    let (best_spec, best_imp) = best.unwrap();
    println!(
        "\nbest policy for this app: {} ({:+.2}% vs fixed (20,20))",
        best_spec.spec_string(),
        best_imp
    );

    // The computation-sensitive FedTune controller must slash E (Table 3:
    // both CompT and CompL prefer small E).
    let ft = result.find_cell(|c| c.tuner == TunerSpec::FedTune).unwrap();
    anyhow::ensure!(
        ft.final_e.mean < 20.0,
        "expected E to shrink for a computation-sensitive app, got {:.1}",
        ft.final_e.mean
    );
    println!("E shrank as Table 3 predicts for computation-sensitive apps ✓");
    Ok(())
}
