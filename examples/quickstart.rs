//! Quickstart: the FedTune public API in ~40 lines.
//!
//! Runs the paper's headline comparison once on the simulator: a fixed
//! (M, E) = (20, 20) baseline vs FedTune with a balanced preference, on
//! the speech-to-command profile with ResNet-10 cost constants.
//!
//!     cargo run --release --example quickstart

use fedtune::baselines;
use fedtune::config::ExperimentConfig;
use fedtune::overhead::Preference;

fn main() -> anyhow::Result<()> {
    // 1. Describe the experiment (dataset, model costs, aggregator, ...).
    let mut cfg = ExperimentConfig::default(); // speech + resnet-10 + fedavg
    cfg.seed = 42;

    // 2. Baseline: fixed hyper-parameters for the whole run.
    let baseline = baselines::run_sim(&cfg, cfg.seed)?;
    println!(
        "baseline  : {} rounds to {:.2} accuracy  CompT {:.3e}  TransT {:.3e}  CompL {:.3e}  TransL {:.3e}",
        baseline.rounds,
        baseline.final_accuracy,
        baseline.costs.comp_t,
        baseline.costs.trans_t,
        baseline.costs.comp_l,
        baseline.costs.trans_l,
    );

    // 3. FedTune: equal care about all four overheads (α=β=γ=δ=0.25).
    cfg.preference = Some(Preference::new(0.25, 0.25, 0.25, 0.25).map_err(anyhow::Error::msg)?);
    let tuned = baselines::run_sim(&cfg, cfg.seed)?;
    println!(
        "fedtune   : {} rounds to {:.2} accuracy  CompT {:.3e}  TransT {:.3e}  CompL {:.3e}  TransL {:.3e}  (final M={}, E={})",
        tuned.rounds,
        tuned.final_accuracy,
        tuned.costs.comp_t,
        tuned.costs.trans_t,
        tuned.costs.comp_l,
        tuned.costs.trans_l,
        tuned.final_m,
        tuned.final_e,
    );

    // 4. The paper's Eq. (6): negative I(baseline, fedtune) = FedTune wins.
    let pref = cfg.preference.unwrap();
    let i = baseline.costs.compare(&tuned.costs, &pref);
    println!("improvement (−I, Eq. 6): {:+.2}%", -i * 100.0);
    Ok(())
}
