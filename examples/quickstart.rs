//! Quickstart: the FedTune public API in ~50 lines.
//!
//! Runs the paper's headline comparison once on the simulator: a fixed
//! (M, E) = (20, 20) baseline vs two tuner policies — FedTune with a
//! balanced preference, and step-wise adaptive decay — on the
//! speech-to-command profile with ResNet-10 cost constants. Policies
//! are picked by spec string, exactly like `fedtune run --tuner ...`.
//!
//!     cargo run --release --example quickstart

use fedtune::baselines;
use fedtune::config::ExperimentConfig;
use fedtune::fedtune::tuner::TunerSpec;
use fedtune::overhead::Preference;

fn main() -> anyhow::Result<()> {
    // 1. Describe the experiment (dataset, model costs, aggregator, ...).
    let mut cfg = ExperimentConfig::default(); // speech + resnet-10 + fedavg
    cfg.seed = 42;

    let report = |name: &str, r: &fedtune::coordinator::RunResult| {
        println!(
            "{name:<10}: {} rounds to {:.2} accuracy  CompT {:.3e}  TransT {:.3e}  \
             CompL {:.3e}  TransL {:.3e}  (final M={}, E={}, {} tuner decisions)",
            r.rounds,
            r.final_accuracy,
            r.costs.comp_t,
            r.costs.trans_t,
            r.costs.comp_l,
            r.costs.trans_l,
            r.final_m,
            r.final_e,
            r.decisions.len(),
        );
    };

    // 2. Baseline: the `fixed` policy holds (M₀, E₀) for the whole run.
    cfg.tuner = TunerSpec::parse("fixed").map_err(anyhow::Error::msg)?;
    let baseline = baselines::run_sim(&cfg, cfg.seed)?;
    report("baseline", &baseline);

    // 3. FedTune: equal care about all four overheads (α=β=γ=δ=0.25).
    cfg.tuner = TunerSpec::parse("fedtune").map_err(anyhow::Error::msg)?;
    cfg.preference = Some(Preference::new(0.25, 0.25, 0.25, 0.25).map_err(anyhow::Error::msg)?);
    let tuned = baselines::run_sim(&cfg, cfg.seed)?;
    report("fedtune", &tuned);

    // 4. Step-wise adaptive decay: preference-free — on a 12-round
    //    plateau, E decays ×0.7 and M re-expands.
    cfg.tuner = TunerSpec::parse("stepwise:0.7:12").map_err(anyhow::Error::msg)?;
    let stepwise = baselines::run_sim(&cfg, cfg.seed)?;
    report("stepwise", &stepwise);

    // 5. The paper's Eq. (6): negative I(baseline, policy) = policy wins.
    let pref = cfg.preference.unwrap();
    for (name, r) in [("fedtune", &tuned), ("stepwise", &stepwise)] {
        let i = baseline.costs.compare(&r.costs, &pref);
        println!("improvement of {name} over fixed (−I, Eq. 6): {:+.2}%", -i * 100.0);
    }
    Ok(())
}
