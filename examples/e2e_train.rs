//! END-TO-END DRIVER: real federated training through all three layers.
//!
//! Proves the full stack composes: the L1 Pallas dense kernels (inside the
//! AOT-lowered L2 train/eval steps) are executed by the L3 rust
//! coordinator over PJRT, while FedTune adjusts (M, E) online from the
//! measured accuracy and the Eq. 2–5 overhead accounting. No Python runs.
//!
//! Workload: speech-like synthetic federated dataset (211 clients,
//! power-law shard sizes, Dirichlet non-IID), mlp-m (≈145k params — the
//! Table-2 ResNet-18 mirror), FedAvg aggregation with a deliberately
//! conservative client LR (hundreds of rounds of horizon), target 0.90, balanced preference. Both the FedTune run and the fixed (10, 2)
//! baseline are executed for a real Eq. 6 comparison; loss/accuracy curves
//! land in traces/. Requires a `pjrt`-enabled build plus `make artifacts`.
//!
//!     make artifacts && cargo run --release --example e2e_train

use std::time::Instant;

use fedtune::aggregation::AggregatorKind;
use fedtune::config::ExperimentConfig;
use fedtune::coordinator::selection::Selector;
use fedtune::coordinator::{RunResult, Server, ServerConfig};
use fedtune::data::FederatedDataset;
use fedtune::engine::real::{RealEngine, RealEngineConfig};
use fedtune::fedtune::tuner::{FixedTuner, Tuner};
use fedtune::fedtune::{FedTune, FedTuneConfig};
use fedtune::overhead::{CostModel, Preference};
use fedtune::runtime::Runtime;
use fedtune::system::SystemSpec;

const MODEL: &str = "mlp-m";
const TARGET: f64 = 0.90;
const SCALE: f64 = 0.1; // 211 of the 2112 speech clients
const M0: usize = 10;
const E0: f64 = 2.0;
// Deliberately conservative LR so the run spans a few hundred rounds —
// enough optimization horizon for FedTune to act repeatedly.
const LR: f32 = 0.03;
const SEED: u64 = 2024;

fn build_engine(seed: u64) -> anyhow::Result<RealEngine> {
    let runtime = Runtime::new("artifacts")?;
    let cfg = ExperimentConfig {
        dataset: "speech".into(),
        scale: SCALE,
        ..ExperimentConfig::default()
    };
    let profile = cfg.profile()?;
    let dataset = FederatedDataset::generate(&profile, seed);
    RealEngine::new(
        runtime,
        dataset,
        RealEngineConfig {
            model: MODEL.into(),
            lr: LR,
            aggregator: AggregatorKind::FedAvg,
            eval_subsample: 1024,
            seed,
            system: SystemSpec::Homogeneous,
        },
    )
}

fn run(tuner: Box<dyn Tuner>, seed: u64) -> anyhow::Result<(RunResult, f64, u64)> {
    let mut engine = build_engine(seed)?;
    let meta = engine.runtime().manifest().model(MODEL)?.clone();
    let cost_model =
        CostModel::from_flops_params(meta.flops_per_sample, meta.param_count as u64);
    let t0 = Instant::now();
    let result = Server::new(
        &mut engine,
        ServerConfig {
            target_accuracy: TARGET,
            max_rounds: 400,
            cost_model,
            selector: Selector::UniformRandom,
            seed,
        },
        tuner,
    )
    .run()?;
    let wall = t0.elapsed().as_secs_f64();
    let stats = engine.runtime().stats;
    println!(
        "  wall {:.1}s | {} PJRT execs ({:.1}s exec, {:.2}% marshal overhead) | {} local SGD steps",
        wall,
        stats.executions,
        stats.exec_secs(),
        stats.overhead_fraction() * 100.0,
        engine.total_steps,
    );
    Ok((result, wall, engine.total_steps))
}

fn main() -> anyhow::Result<()> {
    println!(
        "e2e: REAL federated training — {MODEL} on speech-like data (scale {SCALE}), \
         FedAvg, target {TARGET}\n"
    );
    std::fs::create_dir_all("traces")?;

    // --- fixed baseline ----------------------------------------------------
    println!("[1/2] fixed baseline (M={M0}, E={E0})");
    let (base, _, _) = run(Box::new(FixedTuner::new(M0, E0)), SEED)?;
    println!(
        "  stop={:?} rounds={} acc={:.3}  CompT={:.3e} TransT={:.3e} CompL={:.3e} TransL={:.3e}",
        base.stop, base.rounds, base.final_accuracy,
        base.costs.comp_t, base.costs.trans_t, base.costs.comp_l, base.costs.trans_l
    );
    base.trace.write_csv("traces/e2e_baseline.csv")?;

    // --- FedTune run ---------------------------------------------------------
    println!("\n[2/2] FedTune (balanced preference, D=10, ε=0.01)");
    let pref = Preference::new(0.25, 0.25, 0.25, 0.25).map_err(anyhow::Error::msg)?;
    // num_clients matches the generated dataset (speech scaled).
    let clients = (2112.0 * SCALE).round() as usize;
    let ft = FedTune::new(pref, FedTuneConfig::paper_defaults(clients), M0, E0)
        .map_err(anyhow::Error::msg)?;
    let (tuned, _, _) = run(Box::new(ft), SEED)?;
    println!(
        "  stop={:?} rounds={} acc={:.3}  CompT={:.3e} TransT={:.3e} CompL={:.3e} TransL={:.3e}  final M={} E={}",
        tuned.stop, tuned.rounds, tuned.final_accuracy,
        tuned.costs.comp_t, tuned.costs.trans_t, tuned.costs.comp_l, tuned.costs.trans_l,
        tuned.final_m, tuned.final_e
    );
    tuned.trace.write_csv("traces/e2e_fedtune.csv")?;

    // --- headline comparison (Eq. 6) -----------------------------------------
    let i = base.costs.compare(&tuned.costs, &pref);
    println!("\nloss curve (fedtune run):");
    for r in tuned.trace.records().iter().step_by((tuned.rounds / 12).max(1)) {
        println!(
            "  round {:>4}  acc {:.3}  loss {:.3}  M={} E={:.0}",
            r.round, r.accuracy, r.train_loss, r.m, r.e
        );
    }
    println!("\nEq. 6 improvement of FedTune over fixed ({M0},{E0}): {:+.2}%", -i * 100.0);
    println!("traces: traces/e2e_baseline.csv, traces/e2e_fedtune.csv");

    anyhow::ensure!(
        base.final_accuracy >= TARGET || tuned.final_accuracy >= TARGET,
        "neither run reached the target — regression in the real pipeline"
    );
    println!("\ne2e OK: all three layers compose, training converges");
    Ok(())
}
