"""L1 Pallas kernel: tiled matrix multiplication.

This is the FLOP carrier of every model in the FedTune model ladder (the
dense layers dominate both forward and backward compute), so it is the
paper's compute hot-spot in our reproduction.

TPU-shaped design (see DESIGN.md §Hardware-Adaptation):

* The grid iterates over (M/bm, N/bn, K/bk) tiles. Each program instance
  holds one ``(bm, bk)`` block of ``a``, one ``(bk, bn)`` block of ``b`` and
  one ``(bm, bn)`` block of ``out`` in VMEM.
* Blocks default to 128x128 — the MXU-native tile — and shrink to the
  operand size for small problems so we never waste VMEM on padding.
* The K-loop is the *innermost* grid dimension, so the output block stays
  resident in VMEM across the whole contraction and serves as the
  accumulator (the out index_map ignores the K grid index, which in Pallas
  keeps the block live across those grid steps).
* Accumulation is in f32 (the output dtype). bf16 inputs hit the MXU
  natively on real TPUs; in this environment the kernel runs under
  ``interpret=True`` because the CPU PJRT plugin cannot execute Mosaic
  custom-calls — see DESIGN.md.

Inputs whose dimensions are not multiples of the block size are
zero-padded by the wrapper and the result is sliced back: zero padding is
exact for matmul.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# MXU-native tile. On small operands we shrink to the operand size.
DEFAULT_BLOCK_M = 128
DEFAULT_BLOCK_N = 128
DEFAULT_BLOCK_K = 128


def _matmul_kernel(a_ref, b_ref, out_ref, *, n_k: int):
    """One (bm, bn) output tile; grid dim 2 walks the K blocks."""
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _zero_acc():
        out_ref[...] = jnp.zeros_like(out_ref)

    # f32 accumulation; MXU matmul on the (bm, bk) x (bk, bn) blocks.
    out_ref[...] += jnp.dot(
        a_ref[...].astype(jnp.float32),
        b_ref[...].astype(jnp.float32),
        preferred_element_type=jnp.float32,
    ).astype(out_ref.dtype)


def _pad_to(x: jax.Array, rows: int, cols: int) -> jax.Array:
    pr, pc = rows - x.shape[0], cols - x.shape[1]
    if pr == 0 and pc == 0:
        return x
    return jnp.pad(x, ((0, pr), (0, pc)))


def vmem_bytes(m: int, n: int, k: int, bm: int, bn: int, bk: int,
               itemsize: int = 4) -> int:
    """VMEM footprint estimate of one program instance (a, b, out blocks)."""
    bm, bn, bk = min(bm, m), min(bn, n), min(bk, k)
    return itemsize * (bm * bk + bk * bn + bm * bn)


@functools.partial(
    jax.jit, static_argnames=("block_m", "block_n", "block_k")
)
def matmul(
    a: jax.Array,
    b: jax.Array,
    *,
    block_m: int = DEFAULT_BLOCK_M,
    block_n: int = DEFAULT_BLOCK_N,
    block_k: int = DEFAULT_BLOCK_K,
) -> jax.Array:
    """``a @ b`` via the tiled Pallas kernel.

    ``a``: (M, K), ``b``: (K, N) → (M, N) in f32.
    Shapes need not be multiples of the block sizes (zero-pad + slice).
    """
    if a.ndim != 2 or b.ndim != 2:
        raise ValueError(f"matmul expects 2-D operands, got {a.shape} @ {b.shape}")
    if a.shape[1] != b.shape[0]:
        raise ValueError(f"contraction mismatch: {a.shape} @ {b.shape}")
    m, k = a.shape
    _, n = b.shape
    bm, bn, bk = min(block_m, m), min(block_n, n), min(block_k, k)
    mp = pl.cdiv(m, bm) * bm
    np_ = pl.cdiv(n, bn) * bn
    kp = pl.cdiv(k, bk) * bk
    a_p = _pad_to(a, mp, kp)
    b_p = _pad_to(b, kp, np_)
    n_k = kp // bk

    out = pl.pallas_call(
        functools.partial(_matmul_kernel, n_k=n_k),
        grid=(mp // bm, np_ // bn, n_k),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), jnp.float32),
        interpret=True,  # CPU PJRT cannot run Mosaic custom-calls.
    )(a_p, b_p)
    return out[:m, :n]
