"""L1 Pallas kernels: the compute hot-spot of the FedTune model family.

Public surface:
* ``matmul.matmul`` -- tiled Pallas matmul (f32 accumulation).
* ``dense.dense``   -- fused matmul + bias + optional ReLU with a custom
  VJP whose backward products also run through the Pallas kernel.
* ``ref``           -- pure-jnp oracles the tests pin everything to.
"""

from .dense import dense
from .matmul import matmul

__all__ = ["dense", "matmul"]
