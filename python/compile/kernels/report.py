"""L1 perf report: VMEM footprint + MXU-utilization *estimates* from the
BlockSpec, per DESIGN.md §8 — interpret=True wallclock is CPU-numpy, not a
TPU proxy, so the optimization signal is structural.

Usage: python -m compile.kernels.report

For each model in the zoo and each dense layer it prints the matmul grid,
the per-instance VMEM footprint (x-block + w-block + out-block), and the
MXU-utilization estimate = (real FLOPs) / (padded-tile FLOPs): tiles whose
dimensions don't fill the 128-lane MXU waste the remainder.
"""

from __future__ import annotations

from .matmul import vmem_bytes
from .. import model as M

VMEM_BYTES = 16 * 1024 * 1024  # v4/v5e per-core VMEM


def tile_report(m: int, k: int, n: int, bm: int = 128, bn: int = 128,
                bk: int = 128) -> dict:
    bm_, bn_, bk_ = min(bm, m), min(bn, n), min(bk, k)
    grid = (
        -(-m // bm_),
        -(-n // bn_),
        -(-k // bk_),
    )
    vmem = vmem_bytes(m, n, k, bm, bn, bk)
    real_flops = 2 * m * k * n
    padded_flops = 2 * (grid[0] * bm_) * (grid[2] * bk_) * (grid[1] * bn_)
    # MXU lane efficiency: last-dim tiles below 128 under-fill the array.
    lane_eff = min(bn_, 128) / 128 * min(bk_, 128) / 128
    return {
        "shape": (m, k, n),
        "block": (bm_, bk_, bn_),
        "grid": grid,
        "vmem_bytes": vmem,
        "vmem_ok": vmem <= VMEM_BYTES,
        "pad_utilization": real_flops / padded_flops,
        "lane_utilization": lane_eff,
        "mxu_estimate": (real_flops / padded_flops) * lane_eff,
    }


def model_report(name: str) -> list[dict]:
    spec = M.MODELS[name]
    rows = []
    b = spec.train_batch
    d = M._dense_input_dim(spec)
    dims = [*spec.hidden, spec.classes]
    for h in dims:
        # fwd: (B,d)x(d,h); bwd dW: (d,B)x(B,h); bwd dx: (B,h)x(h,d)
        for tag, (mm, kk, nn) in {
            "fwd": (b, d, h),
            "dW": (d, b, h),
            "dx": (b, h, d),
        }.items():
            r = tile_report(mm, kk, nn)
            r["layer"] = f"{name}:{tag}:{d}x{h}"
            rows.append(r)
        d = h
    return rows


def main() -> None:
    print(f"{'layer':<28} {'grid':<12} {'vmem':>10} {'pad_util':>9} {'mxu_est':>8}")
    for name in M.MODELS:
        for r in model_report(name):
            print(
                f"{r['layer']:<28} {str(r['grid']):<12} "
                f"{r['vmem_bytes']:>10} {r['pad_utilization']:>9.3f} "
                f"{r['mxu_estimate']:>8.3f}"
            )
            assert r["vmem_ok"], f"VMEM overflow in {r['layer']}"


if __name__ == "__main__":
    main()
