"""L1: fused dense layer (x @ W + b, optional ReLU) with a custom VJP.

Forward and both matmul-shaped backward products run through the tiled
Pallas ``matmul`` kernel, so the whole train-step FLOP volume — forward
activations, dx = g @ Wᵀ and dW = xᵀ @ g — is carried by the L1 kernel.
The bias is fused into the forward kernel (one HBM round-trip saved); the
bias gradient is a cheap reduction left to XLA.

``dense`` is registered with ``jax.custom_vjp`` so that ``jax.grad`` of the
L2 model differentiates *through the Pallas kernels*, not through a
reference implementation. Correctness of the VJP is pinned against
``jax.grad`` of ``ref.dense_ref`` in python/tests/test_vjp.py.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .matmul import matmul, _pad_to  # noqa: F401  (shared padding helper)


def _dense_kernel(x_ref, w_ref, b_ref, out_ref, *, n_k: int, relu: bool):
    """(bm, bn) output tile of x @ W; bias+ReLU fused on the last K step."""
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _zero():
        out_ref[...] = jnp.zeros_like(out_ref)

    out_ref[...] += jnp.dot(
        x_ref[...].astype(jnp.float32),
        w_ref[...].astype(jnp.float32),
        preferred_element_type=jnp.float32,
    )

    @pl.when(k == n_k - 1)
    def _epilogue():
        acc = out_ref[...] + b_ref[...].astype(jnp.float32)[None, :]
        if relu:
            acc = jnp.maximum(acc, 0.0)
        out_ref[...] = acc


def _dense_fwd_pallas(x, w, b, *, relu: bool,
                      block_m: int = 128, block_n: int = 128,
                      block_k: int = 128) -> jax.Array:
    m, k = x.shape
    _, n = w.shape
    bm, bn, bk = min(block_m, m), min(block_n, n), min(block_k, k)
    mp = pl.cdiv(m, bm) * bm
    np_ = pl.cdiv(n, bn) * bn
    kp = pl.cdiv(k, bk) * bk
    x_p = _pad_to(x, mp, kp)
    w_p = _pad_to(w, kp, np_)
    b_p = jnp.pad(b, (0, np_ - n)) if np_ != n else b
    n_k = kp // bk

    out = pl.pallas_call(
        functools.partial(_dense_kernel, n_k=n_k, relu=relu),
        grid=(mp // bm, np_ // bn, n_k),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
            pl.BlockSpec((bn,), lambda i, j, kk: (j,)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), jnp.float32),
        interpret=True,  # CPU PJRT cannot run Mosaic custom-calls.
    )(x_p, w_p, b_p)
    return out[:m, :n]


@functools.partial(jax.custom_vjp, nondiff_argnums=(3,))
def dense(x: jax.Array, w: jax.Array, b: jax.Array, relu: bool = False):
    """Fused ``x @ w + b`` (optionally ReLU) through the Pallas kernel.

    x: (B, K) activations, w: (K, N) weights, b: (N,) bias → (B, N) f32.
    """
    return _dense_fwd_pallas(x, w, b, relu=relu)


def _dense_fwd(x, w, b, relu):
    out = _dense_fwd_pallas(x, w, b, relu=relu)
    # Residuals: inputs always; the post-activation output only when the
    # ReLU mask is needed (out > 0 ⇔ pre-activation > 0 almost everywhere).
    return out, (x, w, out if relu else None)


def _dense_bwd(relu, res, g):
    x, w, out = res
    g = g.astype(jnp.float32)
    if relu:
        g = g * (out > 0.0).astype(jnp.float32)
    # Both matmul-shaped products go through the L1 kernel.
    dx = matmul(g, w.astype(jnp.float32).T)
    dw = matmul(x.astype(jnp.float32).T, g)
    db = jnp.sum(g, axis=0)
    return dx.astype(x.dtype), dw.astype(w.dtype), db.astype(jnp.float32)


dense.defvjp(_dense_fwd, _dense_bwd)
