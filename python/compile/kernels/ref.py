"""Pure-jnp oracles for the Pallas kernels.

Every kernel in this package must match its oracle to float32 tolerance;
``python/tests/`` enforces this with hypothesis sweeps over shapes and
dtypes. The oracles are also what ``jax.grad`` differentiates in the VJP
tests, pinning the custom-VJP backward kernels to the true gradients.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def matmul_ref(a: jax.Array, b: jax.Array) -> jax.Array:
    """f32 reference for kernels.matmul."""
    return jnp.dot(
        a.astype(jnp.float32),
        b.astype(jnp.float32),
        preferred_element_type=jnp.float32,
    )


def dense_ref(x: jax.Array, w: jax.Array, b: jax.Array, *, relu: bool) -> jax.Array:
    """f32 reference for kernels.dense (fused matmul + bias + optional ReLU)."""
    out = matmul_ref(x, w) + b.astype(jnp.float32)[None, :]
    if relu:
        out = jnp.maximum(out, 0.0)
    return out


def dense_vjp_ref(x, w, b, g, *, relu: bool):
    """Reference gradients of ``sum(dense(x, w, b) * g)`` w.r.t. (x, w, b)."""

    def f(x_, w_, b_):
        return jnp.sum(dense_ref(x_, w_, b_, relu=relu) * g)

    return jax.grad(f, argnums=(0, 1, 2))(
        x.astype(jnp.float32), w.astype(jnp.float32), b.astype(jnp.float32)
    )
