"""L2: JAX model family for the FedTune reproduction (build-time only).

The paper's measurement ladder (Table 2) uses ResNet-10/18/26/34 on
32x32 spectrograms; its evaluation uses ResNet-10 (speech), a 2-layer MLP
(EMNIST) and ResNet-10/18 (CIFAR-100). Our synthetic datasets (see
DESIGN.md §Substitutions) feed the same system model, which consumes only
FLOPs-per-sample (C1, C3) and parameter count (C2, C4), so we mirror the
ladder with an MLP family whose FLOP ratios match Table 2's
(x1 / x2.14 / x3.29 / x4.81) plus a small conv net for the speech-like
task. Every dense layer routes through the L1 Pallas kernel
(``kernels.dense``), so the AOT train step's FLOP volume is carried by the
Pallas matmul.

Exported computations (per model, fixed shapes; see aot.py):

* ``train_step(params..., x, y, mask, lr) -> (params'..., loss)``
  one mini-batch of masked-softmax-CE SGD. The FL client loop (L3, rust)
  iterates this over the client's local batches E times per round.
* ``eval_step(params..., x, y, mask) -> (correct, loss_sum)``
  masked top-1 correctness count + summed CE, accumulated by rust over the
  held-out set.

Masking: clients have heterogeneous n_k, while HLO shapes are static. The
last batch is zero-padded and ``mask`` (0/1 per row) excludes padding from
both the loss mean and the gradients.
"""

from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp

from .kernels.dense import dense

# ----------------------------------------------------------------------------
# Model zoo
# ----------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ModelSpec:
    """Static description of one model in the zoo."""

    name: str
    dataset: str  # speech | emnist | cifar
    input_shape: tuple[int, ...]  # per-sample shape fed to the model
    classes: int
    hidden: tuple[int, ...]  # dense hidden widths
    conv_channels: tuple[int, ...] = ()  # conv stage (speech cnn only)
    train_batch: int = 8
    eval_batch: int = 64

    @property
    def flat_input_dim(self) -> int:
        d = 1
        for s in self.input_shape:
            d *= s
        return d


def _mk_ladder() -> dict[str, ModelSpec]:
    """Speech-like MLP ladder mirroring Table 2's FLOP ratios.

    Table 2 (ResNet-10/18/26/34): FLOPs 12.5/26.8/41.1/60.1 M ⇒ ratios
    1 : 2.14 : 3.29 : 4.81. With input 1024 and 35 classes, a single hidden
    layer of width H has ~2·(1024+35)·H FLOPs, linear in H, so widths
    64/137/211/308 reproduce the ratios.
    """
    widths = {"mlp-s": 64, "mlp-m": 137, "mlp-l": 211, "mlp-xl": 308}
    return {
        name: ModelSpec(
            name=name,
            dataset="speech",
            input_shape=(1024,),
            classes=35,
            hidden=(w,),
        )
        for name, w in widths.items()
    }


MODELS: dict[str, ModelSpec] = {
    **_mk_ladder(),
    # Paper §5.1(2): EMNIST with a 1-hidden-layer (200, ReLU) MLP.
    "mlp-emnist": ModelSpec(
        name="mlp-emnist",
        dataset="emnist",
        input_shape=(784,),
        classes=62,
        hidden=(200,),
    ),
    # Paper §5.1(3): CIFAR-100. MLP stand-in sized like ResNet-18's param
    # count direction (wider hidden layer, 100-way output).
    "mlp-cifar": ModelSpec(
        name="mlp-cifar",
        dataset="cifar",
        input_shape=(3072,),
        classes=100,
        hidden=(128,),
    ),
    # Conv stand-in for ResNet-10 on spectrograms: 2 conv stages + pallas
    # dense head. Exercises a non-trivially-shaped param tree end to end.
    "cnn-s": ModelSpec(
        name="cnn-s",
        dataset="speech",
        input_shape=(32, 32, 1),
        classes=35,
        hidden=(64,),
        conv_channels=(8, 16),
    ),
}


# ----------------------------------------------------------------------------
# Parameters
# ----------------------------------------------------------------------------


def param_specs(spec: ModelSpec) -> list[tuple[str, tuple[int, ...]]]:
    """Ordered (name, shape) list — THE param layout contract with rust."""
    out: list[tuple[str, tuple[int, ...]]] = []
    in_ch = spec.input_shape[-1] if spec.conv_channels else 0
    for i, ch in enumerate(spec.conv_channels):
        out.append((f"conv{i}_k", (3, 3, in_ch, ch)))
        out.append((f"conv{i}_b", (ch,)))
        in_ch = ch
    d = _dense_input_dim(spec)
    for i, h in enumerate(spec.hidden):
        out.append((f"w{i}", (d, h)))
        out.append((f"b{i}", (h,)))
        d = h
    out.append(("w_out", (d, spec.classes)))
    out.append(("b_out", (spec.classes,)))
    return out


def _dense_input_dim(spec: ModelSpec) -> int:
    if not spec.conv_channels:
        return spec.flat_input_dim
    # Each conv stage is stride-1 SAME followed by 2x2 max-pool.
    h, w, _ = spec.input_shape
    for _ in spec.conv_channels:
        h, w = h // 2, w // 2
    return h * w * spec.conv_channels[-1]


def init_params(spec: ModelSpec, key: jax.Array) -> list[jax.Array]:
    """He-normal weights, zero biases, in param_specs order."""
    params = []
    for name, shape in param_specs(spec):
        key, sub = jax.random.split(key)
        if name.endswith("_b") or name.startswith("b"):
            params.append(jnp.zeros(shape, jnp.float32))
        else:
            fan_in = 1
            for s in shape[:-1]:
                fan_in *= s
            params.append(
                jax.random.normal(sub, shape, jnp.float32)
                * jnp.sqrt(2.0 / fan_in)
            )
    return params


def param_count(spec: ModelSpec) -> int:
    n = 0
    for _, shape in param_specs(spec):
        c = 1
        for s in shape:
            c *= s
        n += c
    return n


def flops_per_sample(spec: ModelSpec) -> int:
    """Forward-pass FLOPs for one input (the paper's C1 = C3 constant)."""
    flops = 0
    if spec.conv_channels:
        h, w, in_ch = spec.input_shape
        for ch in spec.conv_channels:
            flops += 2 * 3 * 3 * in_ch * ch * h * w
            h, w, in_ch = h // 2, w // 2, ch
    d = _dense_input_dim(spec)
    for hd in spec.hidden:
        flops += 2 * d * hd
        d = hd
    flops += 2 * d * spec.classes
    return flops


# ----------------------------------------------------------------------------
# Forward / loss
# ----------------------------------------------------------------------------


def forward(spec: ModelSpec, params: list[jax.Array], x: jax.Array) -> jax.Array:
    """Logits for a batch. Dense layers go through the L1 Pallas kernel."""
    i = 0
    if spec.conv_channels:
        for _ in spec.conv_channels:
            k, b = params[i], params[i + 1]
            i += 2
            x = jax.lax.conv_general_dilated(
                x,
                k,
                window_strides=(1, 1),
                padding="SAME",
                dimension_numbers=("NHWC", "HWIO", "NHWC"),
            )
            x = jnp.maximum(x + b[None, None, None, :], 0.0)
            x = jax.lax.reduce_window(
                x,
                -jnp.inf,
                jax.lax.max,
                window_dimensions=(1, 2, 2, 1),
                window_strides=(1, 2, 2, 1),
                padding="VALID",
            )
        x = x.reshape(x.shape[0], -1)
    else:
        x = x.reshape(x.shape[0], -1)
    for _ in spec.hidden:
        w, b = params[i], params[i + 1]
        i += 2
        x = dense(x, w, b, True)
    w, b = params[i], params[i + 1]
    return dense(x, w, b, False)


def masked_ce(logits: jax.Array, y: jax.Array, mask: jax.Array) -> jax.Array:
    """Mean masked softmax cross-entropy (mask excludes padded rows)."""
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, y[:, None], axis=-1)[:, 0]
    denom = jnp.maximum(jnp.sum(mask), 1.0)
    return jnp.sum(nll * mask) / denom


def make_train_step(spec: ModelSpec) -> Callable:
    """(params..., x, y, mask, lr) -> (params'..., loss) — one SGD batch."""

    def train_step(*args):
        n = len(param_specs(spec))
        params = list(args[:n])
        x, y, mask, lr = args[n], args[n + 1], args[n + 2], args[n + 3]

        def loss_fn(ps):
            return masked_ce(forward(spec, ps, x), y, mask)

        loss, grads = jax.value_and_grad(loss_fn)(params)
        new_params = [p - lr * g for p, g in zip(params, grads)]
        return (*new_params, loss)

    return train_step


#: Chunk sizes exported for `train_chunk` (lax.scan of K mini-batches).
#: Chosen in the §Perf pass: marshalling params host↔device per *step*
#: cost ~19-22% of runtime. The rust client loop greedily picks the
#: largest chunk that fits the remaining batches, so typical small clients
#: use K=4 with little padding waste while data-rich clients amortize the
#: fixed param round-trip over K=16 (see EXPERIMENTS.md §Perf).
TRAIN_CHUNKS = (4, 16)
#: Back-compat alias (single default size).
TRAIN_CHUNK = TRAIN_CHUNKS[-1]


def make_train_chunk(spec: ModelSpec, chunk: int) -> Callable:
    """(params..., xs, ys, masks, lr) -> (params'..., mean_loss).

    Runs `chunk` sequential SGD mini-batches inside one XLA program
    via ``lax.scan``: xs is (K, B, ...), ys/masks are (K, B). Batches whose
    mask is all-zero are exact no-ops (zero loss ⇒ zero grads), so the
    caller can pad the tail of a client's data freely.
    """

    n = len(param_specs(spec))

    def train_chunk(*args):
        params = list(args[:n])
        xs, ys, masks, lr = args[n], args[n + 1], args[n + 2], args[n + 3]

        def body(ps, batch):
            x, y, mask = batch

            def loss_fn(p):
                return masked_ce(forward(spec, p, x), y, mask)

            loss, grads = jax.value_and_grad(loss_fn)(ps)
            return [p - lr * g for p, g in zip(ps, grads)], loss

        params_out, losses = jax.lax.scan(body, params, (xs, ys, masks))
        # Mean over batches that had any real rows.
        weights = (jnp.sum(masks, axis=1) > 0).astype(jnp.float32)
        denom = jnp.maximum(jnp.sum(weights), 1.0)
        mean_loss = jnp.sum(losses * weights) / denom
        return (*params_out, mean_loss)

    return train_chunk


def example_chunk(spec: ModelSpec, chunk: int, batch: int):
    """ShapeDtypeStructs for (xs, ys, masks) of a train chunk."""
    xs = jax.ShapeDtypeStruct((chunk, batch, *spec.input_shape), jnp.float32)
    ys = jax.ShapeDtypeStruct((chunk, batch), jnp.int32)
    masks = jax.ShapeDtypeStruct((chunk, batch), jnp.float32)
    return xs, ys, masks


def make_eval_step(spec: ModelSpec) -> Callable:
    """(params..., x, y, mask) -> (correct, loss_sum) over one batch."""

    def eval_step(*args):
        n = len(param_specs(spec))
        params = list(args[:n])
        x, y, mask = args[n], args[n + 1], args[n + 2]
        logits = forward(spec, params, x)
        pred = jnp.argmax(logits, axis=-1).astype(y.dtype)
        correct = jnp.sum((pred == y).astype(jnp.float32) * mask)
        logp = jax.nn.log_softmax(logits, axis=-1)
        nll = -jnp.take_along_axis(logp, y[:, None], axis=-1)[:, 0]
        return correct, jnp.sum(nll * mask)

    return eval_step


def example_batch(spec: ModelSpec, batch: int):
    """ShapeDtypeStructs for (x, y, mask) at the given batch size."""
    x = jax.ShapeDtypeStruct((batch, *spec.input_shape), jnp.float32)
    y = jax.ShapeDtypeStruct((batch,), jnp.int32)
    mask = jax.ShapeDtypeStruct((batch,), jnp.float32)
    return x, y, mask
