"""AOT exporter: lower L2 train/eval steps to HLO text + manifest.json.

This is the only place Python touches the pipeline; `make artifacts` runs it
once and the rust coordinator (L3) is self-contained afterwards.

Interchange format is HLO *text*, not a serialized HloModuleProto: jax ≥ 0.5
emits protos with 64-bit instruction ids that the xla crate's bundled
xla_extension 0.5.1 rejects (`proto.id() <= INT_MAX`); the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).

The manifest records, per model: the ordered parameter layout (the contract
with rust/src/model), input/output specs of each artifact, FLOPs-per-sample
(the paper's C1=C3 overhead constant) and the parameter count (C2=C4).

Usage: python -m compile.aot --out-dir ../artifacts [--models mlp-s,...]
"""

from __future__ import annotations

import argparse
import hashlib
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model as M


def to_hlo_text(lowered) -> str:
    """StableHLO → XlaComputation → HLO text (id-safe interchange)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _shape_entry(s) -> dict:
    dt = jnp.dtype(s.dtype).name
    return {"shape": list(s.shape), "dtype": dt}


def export_model(spec: M.ModelSpec, out_dir: str) -> dict:
    """Lower train_step and eval_step for one model; return manifest entry."""
    pspecs = M.param_specs(spec)
    param_structs = [
        jax.ShapeDtypeStruct(shape, jnp.float32) for _, shape in pspecs
    ]

    # --- train step -------------------------------------------------------
    x, y, mask = M.example_batch(spec, spec.train_batch)
    lr = jax.ShapeDtypeStruct((), jnp.float32)
    train_lowered = jax.jit(M.make_train_step(spec)).lower(
        *param_structs, x, y, mask, lr
    )
    train_text = to_hlo_text(train_lowered)
    train_path = f"{spec.name}_train.hlo.txt"
    with open(os.path.join(out_dir, train_path), "w") as f:
        f.write(train_text)

    # --- train chunks (scan of K steps; the §Perf hot path) ---------------
    chunk_entries = []
    for k in M.TRAIN_CHUNKS:
        xs, ys, masks = M.example_chunk(spec, k, spec.train_batch)
        chunk_lowered = jax.jit(M.make_train_chunk(spec, k)).lower(
            *param_structs, xs, ys, masks, lr
        )
        chunk_text = to_hlo_text(chunk_lowered)
        chunk_path = f"{spec.name}_train_chunk{k}.hlo.txt"
        with open(os.path.join(out_dir, chunk_path), "w") as f:
            f.write(chunk_text)
        chunk_entries.append(
            {
                "path": chunk_path,
                "batch": spec.train_batch,
                "chunk": k,
                "inputs": [
                    *({"name": n, **_shape_entry(s)} for (n, _), s in zip(pspecs, param_structs)),
                    {"name": "xs", **_shape_entry(xs)},
                    {"name": "ys", **_shape_entry(ys)},
                    {"name": "masks", **_shape_entry(masks)},
                    {"name": "lr", **_shape_entry(lr)},
                ],
                "outputs": [
                    *({"name": n, **_shape_entry(s)} for (n, _), s in zip(pspecs, param_structs)),
                    {"name": "mean_loss", "shape": [], "dtype": "float32"},
                ],
                "sha256": hashlib.sha256(chunk_text.encode()).hexdigest(),
            }
        )

    # --- eval step --------------------------------------------------------
    xe, ye, maske = M.example_batch(spec, spec.eval_batch)
    eval_lowered = jax.jit(M.make_eval_step(spec)).lower(
        *param_structs, xe, ye, maske
    )
    eval_text = to_hlo_text(eval_lowered)
    eval_path = f"{spec.name}_eval.hlo.txt"
    with open(os.path.join(out_dir, eval_path), "w") as f:
        f.write(eval_text)

    return {
        "dataset": spec.dataset,
        "input_shape": list(spec.input_shape),
        "classes": spec.classes,
        "params": [
            {"name": name, "shape": list(shape)} for name, shape in pspecs
        ],
        "param_count": M.param_count(spec),
        "flops_per_sample": M.flops_per_sample(spec),
        "train": {
            "path": train_path,
            "batch": spec.train_batch,
            "inputs": [
                *({"name": n, **_shape_entry(s)} for (n, _), s in zip(pspecs, param_structs)),
                {"name": "x", **_shape_entry(x)},
                {"name": "y", **_shape_entry(y)},
                {"name": "mask", **_shape_entry(mask)},
                {"name": "lr", **_shape_entry(lr)},
            ],
            "outputs": [
                *({"name": n, **_shape_entry(s)} for (n, _), s in zip(pspecs, param_structs)),
                {"name": "loss", "shape": [], "dtype": "float32"},
            ],
            "sha256": hashlib.sha256(train_text.encode()).hexdigest(),
        },
        "train_chunks": chunk_entries,
        "eval": {
            "path": eval_path,
            "batch": spec.eval_batch,
            "inputs": [
                *({"name": n, **_shape_entry(s)} for (n, _), s in zip(pspecs, param_structs)),
                {"name": "x", **_shape_entry(xe)},
                {"name": "y", **_shape_entry(ye)},
                {"name": "mask", **_shape_entry(maske)},
            ],
            "outputs": [
                {"name": "correct", "shape": [], "dtype": "float32"},
                {"name": "loss_sum", "shape": [], "dtype": "float32"},
            ],
            "sha256": hashlib.sha256(eval_text.encode()).hexdigest(),
        },
    }


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument(
        "--models",
        default=",".join(M.MODELS),
        help="comma-separated subset of: " + ", ".join(M.MODELS),
    )
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)

    manifest = {"format_version": 1, "jax_version": jax.__version__, "models": {}}
    for name in args.models.split(","):
        name = name.strip()
        if not name:
            continue
        if name not in M.MODELS:
            raise SystemExit(f"unknown model {name!r}; have {list(M.MODELS)}")
        print(f"[aot] lowering {name} ...", flush=True)
        manifest["models"][name] = export_model(M.MODELS[name], args.out_dir)

    with open(os.path.join(args.out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2, sort_keys=True)
    print(f"[aot] wrote {len(manifest['models'])} models to {args.out_dir}")


if __name__ == "__main__":
    main()
