"""Build-time Python: L1 Pallas kernels + L2 JAX models + the AOT exporter.

Never imported at runtime -- `make artifacts` runs `compile.aot` once and
the rust coordinator executes the lowered HLO through PJRT afterwards.
"""
