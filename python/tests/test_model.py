"""L2 model-layer tests: shapes, masking semantics, SGD descent, zoo
consistency with the Table-2 ratio contract."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as M


@pytest.fixture(scope="module", params=["mlp-s", "mlp-emnist", "cnn-s"])
def spec(request):
    return M.MODELS[request.param]


def _batch(spec, b, seed=0):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(size=(b, *spec.input_shape)) * 0.5, jnp.float32)
    y = jnp.asarray(rng.integers(0, spec.classes, size=(b,)), jnp.int32)
    mask = jnp.ones((b,), jnp.float32)
    return x, y, mask


def test_zoo_has_expected_models():
    for name in ["mlp-s", "mlp-m", "mlp-l", "mlp-xl", "mlp-emnist", "mlp-cifar", "cnn-s"]:
        assert name in M.MODELS


def test_ladder_flop_ratios_mirror_table2():
    base = M.flops_per_sample(M.MODELS["mlp-s"])
    ratios = [
        M.flops_per_sample(M.MODELS[n]) / base
        for n in ["mlp-s", "mlp-m", "mlp-l", "mlp-xl"]
    ]
    for r, expect in zip(ratios, [1.0, 2.144, 3.288, 4.808]):
        assert abs(r - expect) / expect < 0.02, (r, expect)


def test_param_specs_match_init(spec):
    params = M.init_params(spec, jax.random.PRNGKey(0))
    specs = M.param_specs(spec)
    assert len(params) == len(specs)
    for p, (name, shape) in zip(params, specs):
        assert p.shape == shape, name
    assert M.param_count(spec) == sum(int(np.prod(s)) for _, s in specs)


def test_forward_shape(spec):
    params = M.init_params(spec, jax.random.PRNGKey(1))
    x, _, _ = _batch(spec, 4)
    logits = M.forward(spec, params, x)
    assert logits.shape == (4, spec.classes)
    assert bool(jnp.isfinite(logits).all())


def test_biases_init_to_zero(spec):
    params = M.init_params(spec, jax.random.PRNGKey(2))
    for p, (name, _) in zip(params, M.param_specs(spec)):
        if name.startswith("b") or name.endswith("_b"):
            assert float(jnp.abs(p).max()) == 0.0, name


def test_masked_ce_ignores_padding():
    logits = jnp.asarray(np.random.default_rng(0).normal(size=(6, 10)), jnp.float32)
    y = jnp.zeros((6,), jnp.int32)
    full = M.masked_ce(logits[:3], y[:3], jnp.ones((3,), jnp.float32))
    padded = M.masked_ce(
        logits, y, jnp.asarray([1, 1, 1, 0, 0, 0], jnp.float32)
    )
    np.testing.assert_allclose(full, padded, rtol=1e-6)


def test_all_zero_mask_gives_zero_loss():
    logits = jnp.ones((4, 5), jnp.float32)
    y = jnp.zeros((4,), jnp.int32)
    loss = M.masked_ce(logits, y, jnp.zeros((4,), jnp.float32))
    assert float(loss) == 0.0


def test_train_step_descends(spec):
    params = M.init_params(spec, jax.random.PRNGKey(3))
    step = jax.jit(M.make_train_step(spec))
    x, y, mask = _batch(spec, spec.train_batch, seed=7)
    lr = jnp.float32(0.1)
    out = step(*params, x, y, mask, lr)
    first_loss = float(out[-1])
    params = list(out[:-1])
    for _ in range(8):
        out = step(*params, x, y, mask, lr)
        params = list(out[:-1])
    assert float(out[-1]) < first_loss


def test_train_step_respects_mask(spec):
    # Gradients from masked rows must not move parameters.
    params = M.init_params(spec, jax.random.PRNGKey(4))
    step = jax.jit(M.make_train_step(spec))
    b = spec.train_batch
    x, y, _ = _batch(spec, b, seed=8)
    zero_mask = jnp.zeros((b,), jnp.float32)
    out = step(*params, x, y, zero_mask, jnp.float32(0.5))
    for p0, p1 in zip(params, out[:-1]):
        np.testing.assert_allclose(p0, p1, rtol=0, atol=0)


def test_eval_step_counts(spec):
    params = M.init_params(spec, jax.random.PRNGKey(5))
    estep = jax.jit(M.make_eval_step(spec))
    x, y, mask = _batch(spec, spec.eval_batch, seed=9)
    correct, loss_sum = estep(*params, x, y, mask)
    assert 0.0 <= float(correct) <= spec.eval_batch
    assert float(loss_sum) > 0.0
    # Masked rows don't count.
    c2, _ = estep(*params, x, y, jnp.zeros_like(mask))
    assert float(c2) == 0.0


def test_eval_step_perfect_when_logits_match():
    # With an identity-ish construction, a sample whose feature equals a
    # one-hot class direction is classified correctly.
    spec = M.MODELS["mlp-emnist"]
    params = M.init_params(spec, jax.random.PRNGKey(6))
    step = jax.jit(M.make_train_step(spec))
    x, y, mask = _batch(spec, spec.train_batch, seed=10)
    # Overfit one batch hard; accuracy on it should exceed chance strongly.
    ps = list(params)
    for _ in range(60):
        out = step(*ps, x, y, mask, jnp.float32(0.3))
        ps = list(out[:-1])
    logits = M.forward(spec, ps, x)
    acc = float(jnp.mean((jnp.argmax(logits, -1) == y).astype(jnp.float32)))
    assert acc > 0.8, acc
