"""AOT exporter tests: manifest consistency, HLO text sanity, and the
rust-layout contract (param ordering, input/output signatures)."""

import json
import os

import jax
import jax.numpy as jnp
import pytest

from compile import aot
from compile import model as M


@pytest.fixture(scope="module")
def exported(tmp_path_factory):
    out = tmp_path_factory.mktemp("artifacts")
    entry = aot.export_model(M.MODELS["mlp-s"], str(out))
    return out, entry


def test_hlo_text_files_exist_and_parse_shape(exported):
    out, entry = exported
    for key in ("train", "eval"):
        path = os.path.join(out, entry[key]["path"])
        assert os.path.exists(path)
        text = open(path).read()
        # HLO text module headers the rust-side parser requires.
        assert text.startswith("HloModule"), text[:50]
        assert "ENTRY" in text


def test_manifest_entry_matches_model(exported):
    _, entry = exported
    spec = M.MODELS["mlp-s"]
    assert entry["classes"] == spec.classes
    assert entry["param_count"] == M.param_count(spec)
    assert entry["flops_per_sample"] == M.flops_per_sample(spec)
    names = [p["name"] for p in entry["params"]]
    assert names == [n for n, _ in M.param_specs(spec)]


def test_train_signature_contract(exported):
    _, entry = exported
    spec = M.MODELS["mlp-s"]
    n_params = len(M.param_specs(spec))
    inputs = entry["train"]["inputs"]
    # Ordered contract with rust: params..., x, y, mask, lr.
    assert [i["name"] for i in inputs[n_params:]] == ["x", "y", "mask", "lr"]
    assert inputs[n_params]["shape"] == [spec.train_batch, *spec.input_shape]
    assert inputs[n_params + 1]["dtype"] == "int32"
    assert inputs[-1]["shape"] == []
    outputs = entry["train"]["outputs"]
    assert len(outputs) == n_params + 1
    assert outputs[-1]["name"] == "loss"


def test_eval_signature_contract(exported):
    _, entry = exported
    outs = entry["eval"]["outputs"]
    assert [o["name"] for o in outs] == ["correct", "loss_sum"]


def test_sha256_matches_file(exported):
    import hashlib

    out, entry = exported
    text = open(os.path.join(out, entry["train"]["path"])).read()
    assert hashlib.sha256(text.encode()).hexdigest() == entry["train"]["sha256"]


def test_full_manifest_roundtrip(tmp_path):
    # Run the main() path over two models and parse the manifest like rust.
    import sys
    from unittest import mock

    argv = [
        "aot",
        "--out-dir",
        str(tmp_path),
        "--models",
        "mlp-s,mlp-emnist",
    ]
    with mock.patch.object(sys, "argv", argv):
        aot.main()
    manifest = json.load(open(tmp_path / "manifest.json"))
    assert manifest["format_version"] == 1
    assert set(manifest["models"]) == {"mlp-s", "mlp-emnist"}
    for entry in manifest["models"].values():
        declared = sum(
            int(jnp.prod(jnp.asarray(p["shape"]))) for p in entry["params"]
        )
        assert declared == entry["param_count"]


def test_unknown_model_rejected(tmp_path):
    import sys
    from unittest import mock

    argv = ["aot", "--out-dir", str(tmp_path), "--models", "mlp-nope"]
    with mock.patch.object(sys, "argv", argv):
        with pytest.raises(SystemExit):
            aot.main()


def test_lowered_train_step_runs_and_descends():
    # Execute the jitted (pre-lowering) train step — the exact computation
    # that gets exported — and verify SGD descends on a fixed batch.
    spec = M.MODELS["mlp-s"]
    step = jax.jit(M.make_train_step(spec))
    params = M.init_params(spec, jax.random.PRNGKey(0))
    k = jax.random.PRNGKey(1)
    x = jax.random.normal(k, (spec.train_batch, *spec.input_shape), jnp.float32)
    y = jnp.arange(spec.train_batch, dtype=jnp.int32) % spec.classes
    mask = jnp.ones((spec.train_batch,), jnp.float32)
    out = step(*params, x, y, mask, jnp.float32(0.1))
    first = float(out[-1])
    ps = list(out[:-1])
    for _ in range(5):
        out = step(*ps, x, y, mask, jnp.float32(0.1))
        ps = list(out[:-1])
    assert float(out[-1]) < first
