"""Custom-VJP correctness: gradients through the Pallas kernels must match
``jax.grad`` of the pure-jnp reference. This pins the backward kernels
(dx = g Wᵀ, dW = xᵀ g, db = Σg, ReLU masking) to the true gradients, so
the AOT train_step the rust engine executes performs genuine SGD.
"""

import hypothesis
import hypothesis.strategies as st
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile.kernels.dense import dense
from compile.kernels.ref import dense_vjp_ref

hypothesis.settings.register_profile(
    "vjp", deadline=None, max_examples=20, derandomize=True
)
hypothesis.settings.load_profile("vjp")


def _case(seed, b, k, n):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(size=(b, k)), jnp.float32)
    w = jnp.asarray(rng.normal(size=(k, n)), jnp.float32)
    bias = jnp.asarray(rng.normal(size=(n,)), jnp.float32)
    g = jnp.asarray(rng.normal(size=(b, n)), jnp.float32)
    return x, w, bias, g


@pytest.mark.parametrize("relu", [False, True])
@hypothesis.given(
    b=st.integers(1, 32), k=st.integers(1, 200), n=st.integers(1, 96),
    seed=st.integers(0, 2**16),
)
def test_dense_vjp_matches_ref(relu, b, k, n, seed):
    x, w, bias, g = _case(seed, b, k, n)

    def loss(x_, w_, b_):
        return jnp.sum(dense(x_, w_, b_, relu) * g)

    dx, dw, db = jax.grad(loss, argnums=(0, 1, 2))(x, w, bias)
    rdx, rdw, rdb = dense_vjp_ref(x, w, bias, g, relu=relu)
    np.testing.assert_allclose(dx, rdx, rtol=1e-3, atol=1e-3)
    np.testing.assert_allclose(dw, rdw, rtol=1e-3, atol=1e-3)
    np.testing.assert_allclose(db, rdb, rtol=1e-3, atol=1e-3)


def test_vjp_composes_through_two_layers():
    # Gradients must flow through stacked Pallas layers (the L2 MLP shape).
    x, w1, b1, _ = _case(0, 8, 64, 32)
    _, w2, b2, _ = _case(1, 8, 32, 10)
    y = jnp.zeros((8,), jnp.int32)

    def loss(w1_, b1_, w2_, b2_):
        h = dense(x, w1_, b1_, True)
        logits = dense(h, w2_, b2_, False)
        logp = jax.nn.log_softmax(logits)
        return -jnp.mean(jnp.take_along_axis(logp, y[:, None], axis=-1))

    grads = jax.grad(loss, argnums=(0, 1, 2, 3))(w1, b1, w2, b2)

    def loss_ref(w1_, b1_, w2_, b2_):
        h = jnp.maximum(x @ w1_ + b1_, 0.0)
        logits = h @ w2_ + b2_
        logp = jax.nn.log_softmax(logits)
        return -jnp.mean(jnp.take_along_axis(logp, y[:, None], axis=-1))

    grads_ref = jax.grad(loss_ref, argnums=(0, 1, 2, 3))(w1, b1, w2, b2)
    for g, gr in zip(grads, grads_ref):
        np.testing.assert_allclose(g, gr, rtol=1e-3, atol=1e-4)


def test_relu_mask_blocks_gradient():
    # Rows pushed fully negative must receive zero dx through ReLU.
    x = jnp.array([[1.0, 1.0]], jnp.float32)
    w = jnp.array([[-10.0], [-10.0]], jnp.float32)  # pre-activation −20
    b = jnp.zeros((1,), jnp.float32)

    def f(x_):
        return jnp.sum(dense(x_, w, b, True))

    dx = jax.grad(f)(x)
    np.testing.assert_allclose(dx, np.zeros_like(dx))


def test_finite_difference_spotcheck():
    # Independent of ref.py: check dW against central differences.
    x, w, bias, _ = _case(5, 4, 6, 3)

    def f(w_):
        return float(jnp.sum(dense(x, w_, bias, True) ** 2))

    dw = jax.grad(lambda w_: jnp.sum(dense(x, w_, bias, True) ** 2))(w)
    eps = 1e-3
    for idx in [(0, 0), (3, 2), (5, 1)]:
        wp = w.at[idx].add(eps)
        wm = w.at[idx].add(-eps)
        fd = (f(wp) - f(wm)) / (2 * eps)
        assert abs(fd - float(dw[idx])) < 5e-2, (idx, fd, float(dw[idx]))
