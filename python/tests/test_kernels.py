"""L1 correctness: Pallas kernels vs the pure-jnp oracle (ref.py).

Hypothesis sweeps shapes and dtypes; every case asserts allclose against
the reference. This is the core correctness signal of the compile path —
if these pass, the HLO the rust runtime executes embodies the same math
as ref.py.
"""

import hypothesis
import hypothesis.strategies as st
import jax.numpy as jnp
import numpy as np
import pytest

from compile.kernels.dense import dense
from compile.kernels.matmul import matmul, vmem_bytes
from compile.kernels.ref import dense_ref, matmul_ref

hypothesis.settings.register_profile(
    "kernels", deadline=None, max_examples=25, derandomize=True
)
hypothesis.settings.load_profile("kernels")

DIMS = st.integers(min_value=1, max_value=300)


def _rand(rng, shape, dtype):
    x = rng.normal(size=shape)
    return jnp.asarray(x, dtype=dtype)


@hypothesis.given(m=DIMS, k=DIMS, n=DIMS, seed=st.integers(0, 2**16))
def test_matmul_matches_ref_f32(m, k, n, seed):
    rng = np.random.default_rng(seed)
    a = _rand(rng, (m, k), jnp.float32)
    b = _rand(rng, (k, n), jnp.float32)
    np.testing.assert_allclose(
        matmul(a, b), matmul_ref(a, b), rtol=1e-4, atol=1e-4
    )


@hypothesis.given(
    m=st.integers(1, 64), k=st.integers(1, 64), n=st.integers(1, 64),
    seed=st.integers(0, 2**16),
)
def test_matmul_matches_ref_bf16_inputs(m, k, n, seed):
    # bf16 inputs, f32 accumulation — the MXU-native configuration.
    rng = np.random.default_rng(seed)
    a = _rand(rng, (m, k), jnp.bfloat16)
    b = _rand(rng, (k, n), jnp.bfloat16)
    np.testing.assert_allclose(
        matmul(a, b), matmul_ref(a, b), rtol=2e-2, atol=2e-2
    )


@hypothesis.given(
    m=st.integers(1, 140), k=st.integers(1, 140), n=st.integers(1, 140),
    bm=st.sampled_from([8, 32, 128]),
    bn=st.sampled_from([8, 32, 128]),
    bk=st.sampled_from([8, 32, 128]),
)
def test_matmul_block_shape_invariance(m, k, n, bm, bn, bk):
    # The result must not depend on the tiling.
    rng = np.random.default_rng(m * 1000 + k * 100 + n)
    a = _rand(rng, (m, k), jnp.float32)
    b = _rand(rng, (k, n), jnp.float32)
    out = matmul(a, b, block_m=bm, block_n=bn, block_k=bk)
    np.testing.assert_allclose(out, matmul_ref(a, b), rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("relu", [False, True])
@hypothesis.given(
    b=st.integers(1, 40), k=st.integers(1, 256), n=st.integers(1, 128),
    seed=st.integers(0, 2**16),
)
def test_dense_matches_ref(relu, b, k, n, seed):
    rng = np.random.default_rng(seed)
    x = _rand(rng, (b, k), jnp.float32)
    w = _rand(rng, (k, n), jnp.float32)
    bias = _rand(rng, (n,), jnp.float32)
    np.testing.assert_allclose(
        dense(x, w, bias, relu),
        dense_ref(x, w, bias, relu=relu),
        rtol=1e-4,
        atol=1e-4,
    )


def test_dense_relu_actually_clamps():
    x = jnp.array([[-100.0, 0.0]], jnp.float32)
    w = jnp.eye(2, dtype=jnp.float32)
    b = jnp.zeros((2,), jnp.float32)
    out = dense(x, w, b, True)
    assert (np.asarray(out) >= 0).all()


def test_matmul_rejects_bad_shapes():
    a = jnp.zeros((2, 3), jnp.float32)
    b = jnp.zeros((4, 5), jnp.float32)
    with pytest.raises(ValueError):
        matmul(a, b)
    with pytest.raises(ValueError):
        matmul(a.reshape(-1), b)


def test_matmul_identity():
    rng = np.random.default_rng(0)
    a = _rand(rng, (50, 50), jnp.float32)
    eye = jnp.eye(50, dtype=jnp.float32)
    np.testing.assert_allclose(matmul(a, eye), a, rtol=1e-5, atol=1e-5)


def test_matmul_zero_padding_exact():
    # Non-multiple-of-block shapes must be exact, not approximately padded.
    rng = np.random.default_rng(1)
    a = _rand(rng, (129, 257), jnp.float32)
    b = _rand(rng, (257, 130), jnp.float32)
    np.testing.assert_allclose(matmul(a, b), matmul_ref(a, b), rtol=1e-4, atol=1e-4)


def test_vmem_estimate_is_sane():
    # 128^3 f32 tiling: 3 blocks x 64 KiB = 192 KiB, far under 16 MiB VMEM.
    assert vmem_bytes(1024, 1024, 1024, 128, 128, 128) == 3 * 128 * 128 * 4
    # Degenerate problems shrink the footprint.
    assert vmem_bytes(8, 8, 8, 128, 128, 128) == 3 * 8 * 8 * 4
